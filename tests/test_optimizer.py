"""Optimizers: AdamW math vs a hand-rolled reference, schedules, clipping,
weight-decay masks, Adafactor memory shape, bf16-moment accuracy."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.optimizer import (
    OptConfig,
    apply_updates,
    global_norm,
    init_opt_state,
    schedule,
)


def _tiny_params():
    k = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "norm": jnp.ones((16,), jnp.float32),
    }


def test_adamw_matches_reference():
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10**9, b1=0.9, b2=0.999,
                    eps=1e-8, weight_decay=0.0, clip_norm=0.0, min_lr_ratio=1.0)
    params = _tiny_params()
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    state = init_opt_state(params, cfg)
    p1, s1, _ = apply_updates(params, grads, state, cfg)
    # reference: bias-corrected adam, step 1 -> mhat = g, vhat = g^2
    g = 0.1
    expected_delta = cfg.lr * g / (np.sqrt(g * g) + cfg.eps)
    got = float((params["w"] - p1["w"])[0, 0])
    assert abs(got - expected_delta) < 1e-6


def test_weight_decay_mask_skips_norms():
    cfg = OptConfig(lr=1e-2, warmup_steps=0, weight_decay=0.5, clip_norm=0.0,
                    min_lr_ratio=1.0, total_steps=10**9)
    params = _tiny_params()
    grads = jax.tree.map(jnp.zeros_like, params)
    state = init_opt_state(params, cfg)
    p1, _, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(p1["norm"] - params["norm"]).max()) == 0.0  # 1-D: no decay
    assert float(jnp.abs(p1["w"] - params["w"]).max()) > 0.0  # 2-D: decayed


def test_grad_clipping():
    cfg = OptConfig(lr=1.0, warmup_steps=0, clip_norm=1.0, weight_decay=0.0,
                    min_lr_ratio=1.0, total_steps=10**9)
    params = _tiny_params()
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 100.0, params)
    state = init_opt_state(params, cfg)
    _, _, stats = apply_updates(params, grads, state, cfg)
    assert float(stats["grad_norm"]) > 1.0  # reported pre-clip


def test_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(jnp.int32(s), cfg)) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0 and abs(lrs[2] - 1e-3) < 1e-9
    assert lrs[3] < lrs[2] and abs(lrs[4] - 1e-4) < 1e-8  # cosine to min ratio


def test_bf16_moments_close_to_f32():
    params = _tiny_params()
    g = jax.tree.map(lambda p: jnp.sin(jnp.arange(p.size, dtype=jnp.float32)).reshape(p.shape) * 0.01, params)
    outs = {}
    for mdt in ("float32", "bfloat16"):
        cfg = OptConfig(lr=1e-3, warmup_steps=0, moments_dtype=mdt, clip_norm=0.0,
                        weight_decay=0.0, min_lr_ratio=1.0, total_steps=10**9)
        p, s = params, init_opt_state(params, cfg)
        for _ in range(5):
            p, s, _ = apply_updates(p, g, s, cfg)
        outs[mdt] = p
    rel = float(jnp.abs(outs["bfloat16"]["w"] - outs["float32"]["w"]).max()
                / jnp.abs(outs["float32"]["w"]).max())
    assert rel < 1e-2  # bf16 moments: half the state, <1% trajectory error


def test_adafactor_factored_state_is_small():
    params = {"big": jnp.zeros((512, 1024), jnp.float32)}
    cfg = OptConfig(name="adafactor")
    state = init_opt_state(params, cfg)
    assert state["vr"]["big"].shape == (512,)
    assert state["vc"]["big"].shape == (1024,)
    grads = {"big": jnp.ones((512, 1024), jnp.float32) * 0.01}
    p1, s1, _ = apply_updates(params, grads, state, cfg)
    assert bool(jnp.all(jnp.isfinite(p1["big"])))
    assert float(jnp.abs(p1["big"]).max()) > 0
