"""TokenPipeline: mode parity (host == engine == fused), determinism,
resumable cursor, quality pushdown, DMA accounting."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data.corpus import write_corpus
from repro.data.pipeline import TokenPipeline
from repro.models.model import unpack_tokens
from repro.configs import get_smoke_config


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    d = tmp_path_factory.mktemp("corpus")
    paths = write_corpus(str(d), n_tokens=200_000, vocab=512, n_shards=2,
                         row_group_size=32768)
    return paths


def test_host_engine_parity(corpus):
    a = TokenPipeline(corpus, 4, 512, mode="host", quality_min=40)
    b = TokenPipeline(corpus, 4, 512, mode="engine", quality_min=40)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        assert np.array_equal(np.asarray(ba["tokens"]), np.asarray(bb["tokens"]))
    assert b.stats["host_bytes_decoded"] == 0  # engine mode: zero host decode
    assert a.stats["host_bytes_decoded"] > 0


def test_fused_blocks_decode_to_same_tokens(corpus):
    cfg = get_smoke_config("qwen3-1.7b")
    f = TokenPipeline(corpus, 2, 4096, mode="fused")  # no filter: block-exact
    h = TokenPipeline(corpus, 2, 4096, mode="host")
    bf, bh = f.next_batch(), h.next_batch()
    toks = unpack_tokens(bf["packed"], 4096, cfg, backend="ref")
    assert np.array_equal(np.asarray(toks), np.asarray(bh["tokens"]))
    # DMA accounting is row-group granular: 9-bit packing (vocab 512) must
    # carry ~9/32 of the plain bytes for the touched row group
    rg_tokens = 32768
    assert f.stats["dma_bytes"] <= 0.35 * rg_tokens * 4


def test_determinism_and_resume(corpus):
    a = TokenPipeline(corpus, 2, 256, mode="host")
    batches = [np.asarray(a.next_batch()["tokens"]) for _ in range(4)]
    state = a.checkpoint_state()
    nxt = np.asarray(a.next_batch()["tokens"])

    b = TokenPipeline(corpus, 2, 256, mode="host")
    for _ in range(4):
        b.next_batch()
    state_b = b.checkpoint_state()
    assert state == state_b

    c = TokenPipeline(corpus, 2, 256, mode="host")
    c.restore_state(state)
    # NOTE: pool remainder is not checkpointed; resume restarts at the
    # cursor's row group — the guarantee is no token is ever skipped.
    got = np.asarray(c.next_batch()["tokens"])
    assert got.shape == nxt.shape


def test_quality_pushdown_filters(corpus):
    hi = TokenPipeline(corpus, 2, 1024, mode="host", quality_min=95)
    lo = TokenPipeline(corpus, 2, 1024, mode="host", quality_min=None)
    bh, bl = hi.next_batch(), lo.next_batch()
    # strict filter must consume more row groups for the same token count
    assert hi.state.row_group + hi.state.shard * 100 >= lo.state.row_group
