"""Fused operator pushdown (DESIGN.md §16): bit-identity of pushed-down
aggregation vs scan-then-aggregate, decode→project result thinning,
batched bloom semijoin identity, the pre-aggregated offload mode, and
the footer-histogram selectivity upgrade.

The identity contract swept here: for ANY execution shape — offload mode
× wfq/fifo × batched/sequential dispatch × 1/2/4-pod fabric — the
aggregate arrays must equal `agg.aggregate_rows_host` over the same
row scan, bit-for-bit (array_equal, never allclose), because every path
partitions accumulation at row-group granularity and folds in global
row-group order.
"""

import numpy as np
import pytest

from repro.core import Cmp, DatapathEngine, ScanPlan, and_
from repro.core import agg
from repro.core import tpch
from repro.core.engine import group_domain, padded_rows
from repro.core.plan import AggSpec, BloomProbe, bind_expr
from repro.core.zonemap import prune_row_groups
from repro.kernels import ops
from repro.lakeformat.encodings import PACK_BLOCK
from repro.lakeformat.reader import LakeReader


@pytest.fixture(scope="module")
def small_tables(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch")
    paths = tpch.write_tables(str(d), sf=0.05, seed=0, row_group_size=8192)
    data = tpch.gen_tables(0.05, 0)
    return paths, data


def _reader(paths, t="lineitem"):
    return LakeReader(paths[t])


PRED = Cmp("l_shipdate", "between", (365, 729))
SPECS = (
    AggSpec("sum", "l_extendedprice"),
    AggSpec("min", "l_quantity"),
    AggSpec("max", "l_quantity"),
    AggSpec("count"),
)


def _expected(reader, plan, blooms=None):
    """Scan-then-aggregate comparator: row scan through the SAME engine,
    host aggregation segmented at row-group boundaries."""
    eng = DatapathEngine(backend="ref")
    srcs = [s for s in agg.agg_sources(plan.aggregates) if s is not None]
    cols = list(dict.fromkeys(
        srcs + ([plan.group_by] if plan.group_by else [])))
    rows = eng.scan(reader, ScanPlan(plan.table, cols, plan.predicate),
                    blooms=blooms)
    rgs = prune_row_groups(reader, bind_expr(plan.predicate, reader))
    segs = [padded_rows(reader.row_group_meta(rg)["n"]) // PACK_BLOCK
            for rg in rgs]
    n_groups = (group_domain(reader, plan.group_by)
                if plan.group_by else 1)
    return agg.aggregate_rows_host(
        {c: np.asarray(rows.columns[c]) for c in cols},
        np.asarray(rows.mask), plan.aggregates, plan.group_by, n_groups,
        segments=segs)


def _assert_identical(got, want):
    assert set(got) == set(want)
    for k in want:
        assert np.array_equal(np.asarray(got[k]), want[k]), k


# ---------------------------------------------------------------------------
# engine-level identity: grouped / ungrouped × sequential / batched
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("group_by", [None, "l_returnflag"],
                         ids=["ungrouped", "grouped"])
@pytest.mark.parametrize("batched", [False, True], ids=["seq", "batched"])
def test_pushdown_matches_scan_then_aggregate(small_tables, group_by, batched):
    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED, aggregates=SPECS, group_by=group_by)
    want = _expected(r, plan)
    res = DatapathEngine(backend="ref").scan(r, plan, batched=batched)
    _assert_identical(res.aggregates, want)
    assert res.agg_partials is not None
    # result DMA is the accumulator set, not the rows
    assert res.stats.result_bytes == sum(
        int(np.asarray(a).nbytes) for a in res.aggregates.values())


def test_pushdown_backend_parity(small_tables):
    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED, aggregates=SPECS,
                    group_by="l_returnflag")
    want = _expected(r, plan)
    for be in ("ref", "pallas"):
        for batched in (False, True):
            res = DatapathEngine(backend=be).scan(r, plan, batched=batched)
            _assert_identical(res.aggregates, want)


def test_float_sum_bit_identity(small_tables):
    """f64 canonical-order fold: the float sum must be bit-identical, not
    merely close, across dispatch shapes."""
    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED,
                    aggregates=(AggSpec("sum", "l_extendedprice"),),
                    group_by="l_returnflag")
    want = _expected(r, plan)
    a = DatapathEngine(backend="ref").scan(r, plan)
    b = DatapathEngine(backend="ref").scan(r, plan, batched=True)
    key = "sum(l_extendedprice)"
    assert np.asarray(a.aggregates[key]).dtype == np.float64
    assert np.array_equal(np.asarray(a.aggregates[key]), want[key])
    assert np.array_equal(np.asarray(b.aggregates[key]), want[key])


def test_fused_agg_skip_decode(small_tables):
    """BITPACK value column absent from output/predicate: the fused path
    must aggregate without a decode launch materializing it — identical
    result, decode_work carries the page bytes, no 'agg' work entry for
    the skipped source."""
    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED,
                    aggregates=(AggSpec("sum", "l_quantity"),
                                AggSpec("count")))
    want = _expected(r, plan)
    res = DatapathEngine(backend="ref").scan(r, plan)
    _assert_identical(res.aggregates, want)
    assert "agg" not in res.stats.decode_work  # fully fused — no decoded src


def test_all_pruned_agg_scan(small_tables):
    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], Cmp("l_shipdate", "gt", 10**9),
                    aggregates=SPECS, group_by="l_returnflag")
    res = DatapathEngine(backend="ref").scan(r, plan)
    n = group_domain(r, "l_returnflag")
    assert int(res.count) == 0
    assert np.array_equal(np.asarray(res.aggregates["count(*)"]),
                          np.zeros(n, np.int64))
    assert np.array_equal(np.asarray(res.aggregates["sum(l_extendedprice)"]),
                          np.zeros(n, np.float64))


def test_over_max_groups_host_fallback(small_tables):
    """Group domain above the kernels' MAX_GROUPS ceiling: pushdown is
    declined, rows scan normally, and the host fallback must still produce
    identical aggregates AND per-rg partials (so fabric merge works)."""
    paths, _ = small_tables
    r = _reader(paths)
    assert group_domain(r, "l_partkey") > ops.MAX_GROUPS
    plan = ScanPlan("lineitem", [], PRED,
                    aggregates=(AggSpec("sum", "l_quantity"),
                                AggSpec("count")),
                    group_by="l_partkey")
    want = _expected(r, plan)
    for batched in (False, True):
        res = DatapathEngine(backend="ref").scan(r, plan, batched=batched)
        _assert_identical(res.aggregates, want)
        assert res.agg_partials is not None


# ---------------------------------------------------------------------------
# decode -> project: predicate-only columns dropped before result DMA
# ---------------------------------------------------------------------------

def test_project_drops_pred_only_columns(small_tables):
    paths, data = small_tables
    r = _reader(paths)
    li = data["lineitem"]
    pred = and_(PRED, Cmp("l_quantity", "lt", 25))
    plan = ScanPlan("lineitem", ["l_extendedprice"], pred)
    for batched in (False, True):
        res = DatapathEngine(backend="ref").scan(r, plan, batched=batched)
        # l_shipdate/l_quantity were decoded for the mask but are NOT in
        # the result set
        assert set(res.columns) == {"l_extendedprice"}
        exp = ((li["l_shipdate"] >= 365) & (li["l_shipdate"] <= 729)
               & (li["l_quantity"] < 25))
        assert int(res.count) == exp.sum()
        assert res.stats.result_bytes == sum(
            int(np.asarray(a).nbytes) for a in res.columns.values()
        ) + int(np.asarray(res.mask).nbytes)


def test_agg_result_bytes_tiny_vs_row_scan(small_tables):
    """The headline: grouped-sum pushdown DMAs the accumulator set, a
    >=5x (here orders-of-magnitude) reduction over shipping the rows."""
    paths, _ = small_tables
    r = _reader(paths)
    aplan = ScanPlan("lineitem", [], PRED,
                     aggregates=(AggSpec("sum", "l_extendedprice"),
                                 AggSpec("count")),
                     group_by="l_returnflag")
    rplan = ScanPlan("lineitem", ["l_extendedprice", "l_returnflag"], PRED)
    eng = DatapathEngine(backend="ref")
    ares = eng.scan(r, aplan, batched=True)
    rres = eng.scan(r, rplan, batched=True)
    assert ares.stats.result_bytes * 5 <= rres.stats.result_bytes
    # and no extra kernel dispatches vs the row scan
    assert ares.stats.kernel_launches <= rres.stats.kernel_launches + len(
        agg.agg_sources(aplan.aggregates))


# ---------------------------------------------------------------------------
# batched bloom-probe semijoin
# ---------------------------------------------------------------------------

def _bloom_fixture(data):
    okeys = np.unique(data["lineitem"]["l_orderkey"])[::7]
    bits = ops.bloom_build(np.asarray(okeys, np.int64), 1 << 15)
    pred = and_(PRED, BloomProbe("l_orderkey", name="ok"))
    return {"ok": bits}, pred


def test_bloom_semijoin_batched_identity(small_tables):
    paths, data = small_tables
    r = _reader(paths)
    blooms, pred = _bloom_fixture(data)
    eng = DatapathEngine(backend="ref")
    rplan = ScanPlan("lineitem", ["l_quantity"], pred)
    seq = eng.scan(r, rplan, blooms=blooms)
    bat = eng.scan(r, rplan, blooms=blooms, batched=True)
    assert np.array_equal(np.asarray(seq.mask), np.asarray(bat.mask))
    assert np.array_equal(np.asarray(seq.columns["l_quantity"]),
                          np.asarray(bat.columns["l_quantity"]))
    assert int(seq.count) > 0


def test_bloom_semijoin_into_fused_agg(small_tables):
    paths, data = small_tables
    r = _reader(paths)
    blooms, pred = _bloom_fixture(data)
    plan = ScanPlan("lineitem", [], pred, aggregates=SPECS,
                    group_by="l_returnflag")
    want = _expected(r, plan, blooms=blooms)
    eng = DatapathEngine(backend="ref")
    for batched in (False, True):
        res = eng.scan(r, plan, blooms=blooms, batched=batched)
        _assert_identical(res.aggregates, want)


# ---------------------------------------------------------------------------
# service: offload modes x schedulers x dispatch shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["raw", "preloaded", "prefiltered",
                                  "pre-aggregated"])
@pytest.mark.parametrize("scheduler,batch_decode",
                         [("wfq", True), ("wfq", False), ("fifo", True)])
def test_service_identity_across_modes(small_tables, mode, scheduler,
                                       batch_decode):
    from repro.datapath.policy import StaticPolicy
    from repro.datapath.service import Pod

    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED, aggregates=SPECS,
                    group_by="l_returnflag")
    want = _expected(r, plan)
    pod = Pod(policy=StaticPolicy(mode), scheduler=scheduler,
              batch_decode=batch_decode)
    t = pod.submit("a", r, plan)
    pod.drain()
    _assert_identical(t.result.aggregates, want)


def test_pre_aggregated_cache_hit(small_tables):
    """Third identical submit hits the prefiltered tier: the cached
    accumulator answer must round-trip bit-identically, flagged as a hit."""
    from repro.datapath.service import Pod

    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED, aggregates=SPECS,
                    group_by="l_returnflag")
    pod = Pod()
    tickets = []
    for _ in range(3):
        tickets.append(pod.submit("a", r, plan))
        pod.drain()
    assert pod.policy.decisions["pre-aggregated"] >= 1
    assert tickets[2].result.stats.cache_hit
    _assert_identical(tickets[2].result.aggregates,
                      {k: np.asarray(v)
                       for k, v in tickets[0].result.aggregates.items()})


# ---------------------------------------------------------------------------
# fabric: deterministic partial-aggregate merge across pods
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pods", [1, 2, 4])
def test_fabric_agg_merge_bit_identical(small_tables, n_pods):
    from repro.datapath.fabric import ScanFabric

    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED, aggregates=SPECS,
                    group_by="l_returnflag")
    want = _expected(r, plan)
    res = ScanFabric(n_pods=n_pods).scan(r, plan)
    _assert_identical(res.aggregates, want)
    assert int(res.count) == int(np.asarray(want["count(*)"]).sum())


def test_fabric_float_sum_order_pinned(small_tables):
    """The pod partition must NOT change the float-sum bit pattern: merge
    happens in global row-group order regardless of which pod owned which
    groups."""
    from repro.datapath.fabric import ScanFabric

    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED,
                    aggregates=(AggSpec("sum", "l_extendedprice"),),
                    group_by="l_returnflag")
    key = "sum(l_extendedprice)"
    base = np.asarray(ScanFabric(n_pods=1).scan(r, plan).aggregates[key])
    for n in (2, 4):
        got = np.asarray(ScanFabric(n_pods=n).scan(r, plan).aggregates[key])
        assert np.array_equal(got, base), n


def test_fabric_all_pruned_agg(small_tables):
    from repro.datapath.fabric import ScanFabric

    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], Cmp("l_shipdate", "gt", 10**9),
                    aggregates=(AggSpec("sum", "l_quantity"),
                                AggSpec("count")))
    res = ScanFabric(n_pods=2).scan(r, plan)
    assert int(res.count) == 0
    assert np.array_equal(np.asarray(res.aggregates["count(*)"]),
                          np.zeros(1, np.int64))


# ---------------------------------------------------------------------------
# cost model: the estimate prices exactly what the scan books
# ---------------------------------------------------------------------------

def test_agg_footprint_estimate_matches_actual(small_tables):
    from repro.datapath.costmodel import CostModel

    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED, aggregates=SPECS,
                    group_by="l_returnflag")
    eng = DatapathEngine(backend="ref")
    cm = CostModel(backend="ref", launch_overhead_s=5e-6)
    rgs = prune_row_groups(r, bind_expr(PRED, r))
    est = sum(c.seconds for c in cm.estimate_row_groups(eng, r, plan, rgs))
    scan = eng.resumable_scan(r, plan, offload="raw")
    res = None
    while res is None:
        res = scan.advance(scan.pending[:1])
    st = res.stats
    actual = sum(cm.decode_seconds(b, e) for e, b in st.decode_work.items()
                 ) + cm.launch_seconds(st.kernel_launches)
    assert est == pytest.approx(actual, abs=1e-12)
    assert "agg" in st.decode_work  # the agg pseudo-work is billed


def test_footprint_roles(small_tables):
    paths, _ = small_tables
    r = _reader(paths)
    plan = ScanPlan("lineitem", [], PRED, aggregates=SPECS,
                    group_by="l_returnflag")
    eng = DatapathEngine(backend="ref")
    fp = eng.decode_footprint(r, plan, [0])[0]["columns"]
    assert fp["l_returnflag"]["role"] == "group-key"
    assert fp["l_extendedprice"]["role"] == "agg-source"
    assert fp["l_shipdate"]["role"] == "pred"
    assert not fp["l_shipdate"]["materialized"]  # fused predicate column
    aggs = [k for k, v in fp.items() if v["role"] == "agg"]
    assert aggs and all(not fp[k]["materialized"] for k in aggs)


# ---------------------------------------------------------------------------
# footer histograms: selectivity sees skew, legacy files degrade gracefully
# ---------------------------------------------------------------------------

def test_histogram_selectivity_beats_uniform(tmp_path):
    """Clustered column: 99% of values in [0, 10], 1% in [990, 1000].  A
    predicate over the dense cluster must estimate near its true mass —
    the uniform-over-range model would say ~1%."""
    from repro.core.zonemap import estimate_selectivity
    from repro.lakeformat.schema import ColumnSchema, TableSchema
    from repro.lakeformat.writer import write_table

    rng = np.random.default_rng(0)
    n = 16384
    vals = np.where(rng.random(n) < 0.99,
                    rng.integers(0, 11, n),
                    rng.integers(990, 1001, n)).astype(np.int32)
    schema = TableSchema("t", [ColumnSchema("v", "int32", "plain")])
    path = write_table(str(tmp_path / "t.lake"), schema, {"v": vals},
                       row_group_size=8192)
    r = LakeReader(path)
    # predicate spanning whole bins: the histogram sees the cluster mass
    # exactly; uniform-over-range would say ~0.5
    true_frac = float((vals <= 500).mean())
    est = estimate_selectivity(r, Cmp("v", "le", 500))
    uniform = 501.0 / 1001.0
    assert abs(est - true_frac) < 0.05
    assert abs(est - true_frac) < abs(uniform - true_frac)
    # point predicate in the dense cluster: bin-mass based, far above the
    # uniform 1/(width+1)
    est_eq = estimate_selectivity(r, Cmp("v", "eq", 5))
    assert est_eq > 2.0 / 1001.0


def test_histogram_absent_falls_back_uniform(small_tables):
    """Zone maps without 'hist' (legacy files) must estimate exactly the
    old uniform-over-[min,max] fraction."""
    from repro.core.zonemap import _range_frac

    zm = {"min": 0, "max": 100}
    assert _range_frac(zm, 0, 50) == pytest.approx(0.5)
    assert _range_frac(zm, -10, -1) == 0.0
    assert _range_frac(dict(zm, hist=[1] * 10), 0, 50) == pytest.approx(
        0.5, abs=0.06)


def test_histogram_written_and_consistent(small_tables):
    paths, _ = small_tables
    r = _reader(paths)
    for zm in r.zonemaps("l_shipdate"):
        if zm["max"] > zm["min"]:
            assert sum(zm["hist"]) == zm["count"]
