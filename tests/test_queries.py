"""Analytical query suite vs numpy oracles (the paper's benchmark queries)."""

import numpy as np
import pytest

from repro.core import DatapathEngine, tpch
from repro.core.queries import QUERIES, q1, q6, q12, q14, q15
from repro.lakeformat.reader import LakeReader

SF = 0.05


@pytest.fixture(scope="module")
def env(tmp_path_factory):
    d = tmp_path_factory.mktemp("q")
    paths = tpch.write_tables(str(d), sf=SF, seed=0)
    readers = {k: LakeReader(p) for k, p in paths.items()}
    data = tpch.gen_tables(SF, 0)
    eng = DatapathEngine(backend="ref")
    return eng, readers, data


def test_q6_oracle(env):
    eng, readers, data = env
    li = data["lineitem"]
    r = q6(eng, readers, year_start=365)
    m = (
        (li["l_shipdate"] >= 365) & (li["l_shipdate"] <= 729)
        & (li["l_discount"] >= 0.05 - 1e-4) & (li["l_discount"] <= 0.07 + 1e-4)
        & (li["l_quantity"] < 24)
    )
    exp = float((li["l_extendedprice"][m].astype(np.float64) * li["l_discount"][m]).sum())
    assert r["rows"] == int(m.sum())
    assert abs(r["revenue"] - exp) / max(exp, 1) < 1e-3


def test_q1_oracle(env):
    eng, readers, data = env
    li = data["lineitem"]
    r = q1(eng, readers, delta_days=90)
    m = li["l_shipdate"] <= 2556 - 90
    rf = np.asarray(li["l_returnflag"])[m]
    ls = np.asarray(li["l_linestatus"])[m]
    qty = li["l_quantity"][m]
    for (rfv, lsv), row in r.items():
        sel = (rf == rfv) & (ls == lsv)
        assert row["count"] == sel.sum()
        assert abs(row["sum_qty"] - qty[sel].sum()) / max(qty[sel].sum(), 1) < 1e-3


def test_q14_oracle(env):
    eng, readers, data = env
    li, part = data["lineitem"], data["part"]
    r = q14(eng, readers, month_start=1000)
    m = (li["l_shipdate"] >= 1000) & (li["l_shipdate"] <= 1029)
    ptype = np.asarray(part["p_type"])
    promo = np.char.startswith(ptype[li["l_partkey"][m]], "PROMO")
    rev = (li["l_extendedprice"][m] * (1 - li["l_discount"][m])).astype(np.float64)
    exp = 100.0 * rev[promo].sum() / rev.sum()
    assert abs(r["promo_revenue_pct"] - exp) < 0.2


def test_q15_oracle(env):
    eng, readers, data = env
    li = data["lineitem"]
    r = q15(eng, readers, quarter_start=365)
    m = (li["l_shipdate"] >= 365) & (li["l_shipdate"] <= 454)
    rev = (li["l_extendedprice"][m] * (1 - li["l_discount"][m])).astype(np.float64)
    per = np.zeros(int(li["l_suppkey"].max()) + 1)
    np.add.at(per, li["l_suppkey"][m], rev)
    assert r["suppkey"] == int(per.argmax())
    assert abs(r["revenue"] - per.max()) / per.max() < 1e-3


def test_q12_oracle(env):
    eng, readers, data = env
    li, orders = data["lineitem"], data["orders"]
    r = q12(eng, readers, year_start=730)
    prio = np.asarray(orders["o_orderpriority"])
    sm = np.asarray(li["l_shipmode"])
    for mode in ("MAIL", "SHIP"):
        m = (sm == mode) & (li["l_receiptdate"] >= 730) & (li["l_receiptdate"] <= 730 + 364)
        p = prio[li["l_orderkey"][m]]
        high = np.char.startswith(p, "1-") | np.char.startswith(p, "2-")
        assert r[mode]["high"] == int(high.sum())
        assert r[mode]["low"] == int((~high).sum())


def test_all_queries_run_all_backends(env):
    _, readers, _ = env
    for be in ("ref", "host"):
        eng = DatapathEngine(backend=be)
        for name, q in QUERIES.items():
            out = q(eng, readers)
            assert out is not None, (be, name)
