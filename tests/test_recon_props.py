"""Reconciliation invariants for the honest cost model.

After `drain()` every tenant's cumulative virtual-time charge must equal
its actual decode cost (estimate + correction), virtual time must never
go negative at any tick boundary, and FIFO mode — which never reads the
virtual clocks — must produce identical schedules with reconciliation on
or off.

The invariants live in plain checker functions exercised both by fixed
regression cases (always run) and by a hypothesis property sweep over
service configurations and estimate-doctoring factors (skipped without
`hypothesis`, same policy as tests/test_decode_pool_props.py).
"""

import functools
import tempfile

import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan, tpch
from repro.datapath import DatapathService, StaticPolicy, TenantQuota
from repro.lakeformat.reader import LakeReader

RG_ROWS = 8192
RG_COST = RG_ROWS * 4 * 2


@functools.lru_cache(maxsize=1)
def _lineitem() -> LakeReader:
    d = tempfile.mkdtemp(prefix="tpch_recon_")
    paths = tpch.write_tables(d, sf=0.05, seed=0, sorted_data=True,
                              row_group_size=RG_ROWS)
    return LakeReader(paths["lineitem"])


PLANS = [
    ScanPlan("lineitem", ["l_extendedprice", "l_quantity"]),  # elephant
    ScanPlan("lineitem", ["l_discount", "l_tax"]),  # disjoint elephant
    ScanPlan("lineitem", ["l_extendedprice"],
             Cmp("l_shipdate", "between", (300, 700))),  # mouse
    ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_quantity", "le", 10)),  # fused
]


def _service(scheduler="wfq", tick_bytes=None, hold_ticks=0, reconcile=True,
             weights=()):
    quotas = {t: TenantQuota(weight=w) for t, w in weights}
    return DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        policy=StaticPolicy("raw"), scheduler=scheduler, tick_bytes=tick_bytes,
        hold_ticks=hold_ticks, reconcile=reconcile, quotas=quotas,
    )


def _run_workload(svc, plan_idxs, cheat_factor=1.0):
    """Submit one tenant per plan (tenant i cheats by `cheat_factor` on its
    estimates), then drain while checking vtime non-negativity every tick.
    Returns the per-tenant tickets."""
    reader = _lineitem()
    tickets = {}
    for i, pi in enumerate(plan_idxs):
        tenant = f"t{i}"
        tickets[tenant] = svc.submit(tenant, reader, PLANS[pi])
        if i == 0 and cheat_factor != 1.0:
            req = next(q for q in svc.queue if q.tenant == tenant)
            req.rg_costs = tuple(c * cheat_factor for c in req.rg_costs)
    guard = 0
    while svc.queue:
        svc.tick()
        guard += 1
        assert guard < 10_000, "drain did not converge"
        assert all(v >= 0.0 for v in svc._vtime.values()), svc._vtime
    return tickets


def check_charge_equals_actual(plan_idxs, scheduler="wfq", tick_bytes=None,
                               hold_ticks=0, cheat_factor=1.0, weights=()):
    """With reconciliation on, sched + recon == actual per tenant, every
    ticket completes, and vtime never went negative."""
    svc = _service(scheduler=scheduler, tick_bytes=tick_bytes,
                   hold_ticks=hold_ticks, reconcile=True, weights=weights)
    tickets = _run_workload(svc, plan_idxs, cheat_factor=cheat_factor)
    assert all(t.status == "done" for t in tickets.values())
    tel = svc.telemetry
    for tenant in tickets:
        est = tel.tenant_sched_seconds.get(tenant, 0.0)
        recon = tel.tenant_recon_seconds.get(tenant, 0.0)
        actual = tel.tenant_actual_seconds.get(tenant, 0.0)
        assert est + recon == pytest.approx(actual, rel=1e-9, abs=1e-15), (
            tenant, est, recon, actual)
        assert actual >= 0.0


def check_fifo_unaffected_by_reconcile(plan_idxs, tick_bytes=None,
                                       cheat_factor=1.0):
    """FIFO never consults virtual time, so reconciliation must not change
    WHAT runs WHEN: done ticks and results match with it on and off."""
    def run(reconcile):
        svc = _service(scheduler="fifo", tick_bytes=tick_bytes,
                       reconcile=reconcile)
        tickets = _run_workload(svc, plan_idxs, cheat_factor=cheat_factor)
        return {t: (tk.done_tick, int(tk.result.count)) for t, tk in tickets.items()}

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# fixed regression cases (always run)
# ---------------------------------------------------------------------------

FIXED_CASES = [
    dict(plan_idxs=(0, 1)),  # two honest elephants, unbounded ticks
    dict(plan_idxs=(0, 1), tick_bytes=RG_COST, cheat_factor=0.25),  # 4x cheat
    dict(plan_idxs=(0, 1, 2, 3), tick_bytes=RG_COST * 2, hold_ticks=2),  # holds
    dict(plan_idxs=(3, 2), cheat_factor=4.0,  # over-estimator gets refunds
         weights=(("t0", 2.0), ("t1", 0.5))),
]


@pytest.mark.parametrize("case", FIXED_CASES)
def test_charge_equals_actual_fixed(case):
    check_charge_equals_actual(**case)


@pytest.mark.parametrize("tick_bytes", [None, RG_COST])
@pytest.mark.parametrize("cheat_factor", [1.0, 0.25])
def test_fifo_unaffected_fixed(tick_bytes, cheat_factor):
    check_fifo_unaffected_by_reconcile((0, 2), tick_bytes=tick_bytes,
                                       cheat_factor=cheat_factor)


def test_reconcile_off_still_reports_actuals():
    """The honesty ledger works even when corrections are disabled."""
    svc = _service(reconcile=False)
    _run_workload(svc, (0,), cheat_factor=0.5)
    tel = svc.telemetry
    assert tel.tenant_actual_seconds["t0"] > 0
    assert tel.tenant_recon_seconds.get("t0", 0.0) == 0.0
    assert tel.cost_report()["t0"]["rel_err"] < -0.4  # the 2x lie is visible


# ---------------------------------------------------------------------------
# hypothesis sweep (these two skip without hypothesis; the fixed cases
# above always run, so the invariants are never fully unguarded)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=15)
    @given(
        plan_idxs=st.lists(st.integers(0, len(PLANS) - 1), min_size=1, max_size=4),
        scheduler=st.sampled_from(["wfq", "fifo"]),
        tick_bytes=st.sampled_from([None, 0, RG_COST, RG_COST * 3]),
        hold_ticks=st.integers(0, 2),
        cheat_factor=st.sampled_from([0.25, 0.5, 1.0, 4.0]),
        w0=st.sampled_from([0.5, 1.0, 3.0]),
    )
    def test_charge_equals_actual_property(plan_idxs, scheduler, tick_bytes,
                                           hold_ticks, cheat_factor, w0):
        check_charge_equals_actual(
            tuple(plan_idxs), scheduler=scheduler, tick_bytes=tick_bytes,
            hold_ticks=hold_ticks, cheat_factor=cheat_factor,
            weights=(("t0", w0),),
        )

    @settings(deadline=None, max_examples=10)
    @given(
        plan_idxs=st.lists(st.integers(0, len(PLANS) - 1), min_size=1, max_size=3),
        tick_bytes=st.sampled_from([None, RG_COST]),
        cheat_factor=st.sampled_from([0.25, 1.0, 4.0]),
    )
    def test_fifo_unaffected_property(plan_idxs, tick_bytes, cheat_factor):
        check_fifo_unaffected_by_reconcile(tuple(plan_idxs), tick_bytes=tick_bytes,
                                           cheat_factor=cheat_factor)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_charge_equals_actual_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fifo_unaffected_property():
        pass
