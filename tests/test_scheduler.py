"""Fair-share tick scheduling: WFQ weight-share invariants, elephant-vs-
mice starvation bounds, split-scan bit-identity, cross-tick coalescing
hold windows, and the fetch-simulation reader-identity regression."""

import copy

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ResumableScan, ScanPlan, tpch
from repro.datapath import DatapathService, StaticPolicy, TenantQuota
from repro.lakeformat.reader import LakeReader

RG_ROWS = 8192  # row-group size: sorted l_shipdate => narrow scans hit 1-2 groups


@pytest.fixture(scope="module")
def lineitem(tmp_path_factory):
    d = tmp_path_factory.mktemp("tpch_sched")
    paths = tpch.write_tables(str(d), sf=0.1, seed=0, sorted_data=True,
                              row_group_size=RG_ROWS)
    return LakeReader(paths["lineitem"])


def _service(**kw):
    kw.setdefault("engine", DatapathEngine(backend="ref", cache=BlockCache(1 << 30)))
    kw.setdefault("policy", StaticPolicy("raw"))
    return DatapathService(**kw)


def _elephant(cols=("l_extendedprice", "l_quantity")):
    """Whole-table scan: every row group, no pruning."""
    return ScanPlan("lineitem", list(cols))


def _mouse(day, width=200):
    """Narrow window on the sort column: 1-2 row groups after pruning."""
    return ScanPlan("lineitem", ["l_extendedprice"],
                    Cmp("l_shipdate", "between", (day, day + width)))


def _assert_identical(got, want):
    assert int(got.count) == int(want.count)
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    assert set(got.columns) == set(want.columns)
    for name in want.columns:
        assert np.array_equal(
            np.asarray(got.columns[name]), np.asarray(want.columns[name])
        ), name


RG_COST = RG_ROWS * 4 * 2  # decoded bytes per row group for a 2-column scan


def _padded_bytes(reader, n_cols=2) -> int:
    """Honest decoded bytes for a whole-table n_cols scan: the engine
    materializes PACK_BLOCK-padded rows, so the short last row group still
    bills a full block."""
    from repro.lakeformat.encodings import padded_rows
    return sum(padded_rows(reader.row_group_meta(rg)["n"]) * 4 * n_cols
               for rg in range(reader.n_row_groups))


# ---------------------------------------------------------------------------
# WFQ invariants
# ---------------------------------------------------------------------------

def test_wfq_equal_weights_share_bound(lineitem):
    """While two equal-weight tenants are both backlogged, their charged
    decode-SECONDS (the WFQ currency since the calibrated cost model) never
    diverge by more than one row group's cost — even though their byte
    shares legitimately differ when their columns decode at different
    rates.  Totals equal the honest padded estimates in both currencies."""
    svc = _service(tick_bytes=int(RG_COST * 1.5))
    # disjoint column sets: no cross-tenant pool sharing muddying the charge
    svc.submit("a", lineitem, _elephant(("l_extendedprice", "l_quantity")))
    svc.submit("b", lineitem, _elephant(("l_discount", "l_tax")))
    reqs = {r.tenant: r for r in svc.queue}
    tol = max(max(reqs["a"].rg_costs), max(reqs["b"].rg_costs))
    est_s = {t: sum(r.rg_costs) for t, r in reqs.items()}
    while svc.queue:
        svc.tick()
        still = {t: any(r.tenant == t and r.cursor < len(r.row_groups)
                        for r in svc.queue) for t in ("a", "b")}
        if still["a"] and still["b"]:
            sched = svc.telemetry.tenant_sched_seconds
            assert abs(sched["a"] - sched["b"]) <= tol + 1e-12, sched
    # both ran to completion charged exactly their honest estimates (honest
    # scans reconcile to ~zero), and byte totals match the padded footprint
    sched_s = svc.telemetry.tenant_sched_seconds
    sched_b = svc.telemetry.tenant_sched_bytes
    for t in ("a", "b"):
        assert sched_s[t] == pytest.approx(est_s[t])
        assert sched_b[t] == _padded_bytes(lineitem)
        assert abs(svc.telemetry.tenant_recon_seconds.get(t, 0.0)) < 1e-9


def test_wfq_weighted_share_bound(lineitem):
    """A weight-2 tenant gets twice the decode-seconds of a weight-1
    tenant, within one row group's cost, for as long as both are
    backlogged."""
    svc = _service(
        tick_bytes=int(RG_COST * 1.5),
        quotas={"heavy": TenantQuota(weight=2.0), "light": TenantQuota(weight=1.0)},
    )
    svc.submit("heavy", lineitem, _elephant(("l_extendedprice", "l_quantity")))
    svc.submit("light", lineitem, _elephant(("l_discount", "l_tax")))
    tol = max(max(r.rg_costs) for r in svc.queue)
    checked = 0
    while svc.queue:
        svc.tick()
        still = {t: any(r.tenant == t and r.cursor < len(r.row_groups)
                        for r in svc.queue) for t in ("heavy", "light")}
        if still["heavy"] and still["light"]:
            sched = svc.telemetry.tenant_sched_seconds
            assert abs(sched["heavy"] / 2.0 - sched["light"]) <= tol + 1e-12, sched
            checked += 1
    assert checked > 0  # the invariant was actually exercised


def test_wfq_mice_not_starved_by_elephant(lineitem):
    """Starvation bound: with a pinned elephant, mice p99 ticks-to-complete
    under WFQ stays within 2x their solo (no-elephant) value; FIFO, which
    runs the elephant head-of-line to completion, is strictly worse."""
    mice_days = (300, 900, 1500)

    def run(scheduler, with_elephant):
        svc = _service(scheduler=scheduler, tick_bytes=int(RG_COST * 1.5))
        if with_elephant:
            svc.submit("elephant", lineitem, _elephant())
        mice = [svc.submit(f"mouse{i}", lineitem, _mouse(d))
                for i, d in enumerate(mice_days)]
        svc.drain()
        ticks = [t.done_tick - t.submitted_tick for t in mice]
        return max(ticks)  # p99 over 3 mice == max

    solo = run("wfq", with_elephant=False)
    wfq = run("wfq", with_elephant=True)
    fifo = run("fifo", with_elephant=True)
    assert wfq <= 2 * solo, (solo, wfq, fifo)
    assert fifo > wfq, (solo, wfq, fifo)


def test_split_elephant_completes(lineitem):
    """Preemption must not starve the preempted: the sliced elephant itself
    reaches a terminal state and its split is recorded."""
    svc = _service(tick_bytes=RG_COST)
    t = svc.submit("elephant", lineitem, _elephant())
    for _ in range(3):
        svc.submit("mouse", lineitem, _mouse(600))
    svc.drain()
    assert t.status == "done"
    assert svc.telemetry.counters["split_scans"] >= 1


# ---------------------------------------------------------------------------
# split-scan bit-identity
# ---------------------------------------------------------------------------

SPLIT_PLANS = [
    ScanPlan("lineitem", ["l_extendedprice", "l_quantity"]),  # full scan
    ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
             Cmp("l_shipdate", "between", (365, 1460))),  # fused fast path
    ScanPlan("lineitem", ["l_quantity"], Cmp("l_quantity", "le", 3),
             compact=True),  # compaction crosses slice boundaries
]


@pytest.mark.parametrize("idx", range(len(SPLIT_PLANS)))
def test_split_scan_bit_identical_to_direct(lineitem, idx):
    """A scan sliced across many ticks equals the single-shot engine scan
    bit for bit — for plain, fused, and compacting plans."""
    plan = SPLIT_PLANS[idx]
    direct = DatapathEngine(backend="ref").scan(lineitem, plan)
    svc = _service(tick_bytes=RG_ROWS * 4)  # ~1 column-group per tick
    ticket = svc.submit("t", lineitem, plan)
    svc.drain()
    assert svc.telemetry.counters.get("split_scans", 0) >= 1  # really sliced
    _assert_identical(ticket.result, direct)


def test_resumable_scan_matches_single_shot(lineitem):
    """Engine-level: advancing one row group at a time assembles the same
    result as scan(), and pending() shrinks in dispatch order."""
    plan = ScanPlan("lineitem", ["l_extendedprice", "l_quantity"],
                    Cmp("l_quantity", "lt", 25))
    eng = DatapathEngine(backend="ref")
    rs = ResumableScan(eng, lineitem, plan)
    seen = []
    while rs.result is None:
        nxt = rs.pending[0]
        rs.advance([nxt])
        seen.append(nxt)
    assert seen == sorted(seen)
    _assert_identical(rs.result, DatapathEngine(backend="ref").scan(lineitem, plan))


def test_resumable_scan_rejects_out_of_order_slices(lineitem):
    eng = DatapathEngine(backend="ref")
    rs = ResumableScan(eng, lineitem, _elephant())
    with pytest.raises(AssertionError):
        rs.advance([rs.pending[-1]])


# ---------------------------------------------------------------------------
# cross-tick coalescing window
# ---------------------------------------------------------------------------

PLAN_A = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                  Cmp("l_shipdate", "between", (300, 700)))
PLAN_B = ScanPlan("lineitem", ["l_extendedprice", "l_discount"],
                  Cmp("l_shipdate", "between", (350, 750)))


def test_hold_window_coalesces_across_ticks(lineitem):
    """Compatible requests arriving a tick apart share a DecodePool when a
    hold window is open, and decode independently when it is not."""
    def run(hold):
        svc = _service(hold_ticks=hold)
        a = svc.submit("t0", lineitem, PLAN_A)
        svc.tick()  # without a hold, t0 decodes alone here
        b = svc.submit("t1", lineitem, PLAN_B)
        svc.drain()
        return svc, a, b

    svc0, _, _ = run(0)
    assert svc0.telemetry.counters.get("decoded_bytes_saved", 0) == 0

    svc2, a, b = run(2)
    assert svc2.telemetry.counters["decoded_bytes_saved"] > 0
    assert a.done_tick == b.done_tick  # released into the partner's tick
    assert svc2.telemetry.counters["hold_released"] >= 1
    assert svc2.telemetry.counters["held_ticks"] == 1  # one tick of added latency
    # results unaffected by the detour through the shared pool
    _assert_identical(a.result, DatapathEngine(backend="ref").scan(lineitem, PLAN_A))
    _assert_identical(b.result, DatapathEngine(backend="ref").scan(lineitem, PLAN_B))


def test_hold_window_deadline_always_dispatches(lineitem):
    """A held request with no partner force-dispatches once its deadline
    (hold_ticks) expires — holds add bounded latency, never starvation."""
    svc = _service(hold_ticks=3)
    t = svc.submit("t0", lineitem, PLAN_A)
    for expected_held in (1, 2, 3):
        svc.tick()
        assert t.status == "queued"
        assert svc.telemetry.counters["held_ticks"] == expected_held
    svc.tick()  # deadline: dispatches regardless of partners
    assert t.status == "done"
    assert t.done_tick == 4
    assert svc.telemetry.counters["hold_deadline_dispatch"] == 1
    assert svc.telemetry.counters["held_requests"] == 1


def test_hold_window_result_api_still_blocks_correctly(lineitem):
    """result() on a held ticket must spin through held ticks and return."""
    svc = _service(hold_ticks=5)
    t = svc.submit("t0", lineitem, PLAN_A)
    res = svc.result(t)
    assert int(res.count) > 0


def test_zero_tick_budget_still_progresses(lineitem):
    """A degenerate tick_bytes (0) must not livelock drain(): every tick
    dispatches at least one row group, like FIFO's head-of-line rule."""
    svc = _service(tick_bytes=0)
    t = svc.submit("t", lineitem, _mouse(600))
    ticks = 0
    while svc.queue:
        svc.tick()
        ticks += 1
        assert ticks <= 4 * lineitem.n_row_groups, "no per-tick progress"
    assert t.status == "done"


def test_fully_pruned_request_is_not_held(lineitem):
    """A scan whose predicate prunes every row group has nothing to
    coalesce — holding it can never pay, so it completes on tick 1."""
    impossible = ScanPlan("lineitem", ["l_extendedprice"],
                          Cmp("l_shipdate", "between", (-20, -10)))
    svc = _service(hold_ticks=3)
    t = svc.submit("t0", lineitem, impossible)
    svc.tick()
    assert t.status == "done" and t.done_tick == 1
    assert int(t.result.count) == 0
    assert svc.telemetry.counters.get("held_requests", 0) == 0


def test_incompatible_requests_are_not_held(lineitem):
    """A second request with a disjoint footprint is no coalescing partner:
    both are held to their own deadlines, not released together."""
    svc = _service(hold_ticks=2)
    svc.submit("t0", lineitem, _mouse(200))  # low shipdate rows
    svc.submit("t1", lineitem, _mouse(2200))  # high shipdate rows — disjoint
    svc.tick()
    assert svc.telemetry.counters["held_requests"] == 2
    svc.drain()
    assert svc.telemetry.counters.get("hold_released", 0) == 0


def test_pulled_in_partner_cannot_bypass_tick_budget(lineitem):
    """A fresh elephant compatible with a held mouse must NOT be dumped
    whole into one tick by the coalescing sweep: only row groups already
    dispatched this tick ride free; fresh groups stay budget-bound."""
    svc = _service(hold_ticks=2, tick_bytes=RG_COST)
    mouse = svc.submit("m", lineitem, _mouse(600))
    svc.tick()  # mouse held, waiting for a partner
    el = svc.submit("e", lineitem, _elephant(("l_extendedprice", "l_quantity")))
    svc.drain()
    assert mouse.status == "done" and el.status == "done"
    # with ~1 row group of budget per tick, the elephant must span many
    # ticks (the old sweep dispatched all 8 groups the tick after the hold)
    assert el.done_tick - el.submitted_tick >= lineitem.n_row_groups // 2, (
        el.submitted_tick, el.done_tick)


def test_prefiltered_cache_hit_is_never_held(lineitem):
    """A request the prefiltered cache can answer decodes nothing, so the
    hold window must not delay it waiting for a decode partner."""
    from repro.datapath import AdaptiveOffloadPolicy

    svc = _service(policy=AdaptiveOffloadPolicy(repeat_k=2), hold_ticks=3)
    plan = PLAN_A
    svc.result(svc.submit("t", lineitem, plan))  # seen=1: raw-ish, held+deadline
    svc.result(svc.submit("t", lineitem, plan))  # seen=2: prefiltered, caches
    t3 = svc.submit("t", lineitem, plan)
    svc.tick()
    assert t3.status == "done"  # cache-resident: dispatched immediately
    assert t3.done_tick - t3.submitted_tick == 1
    assert t3.result.stats.cache_hit


# ---------------------------------------------------------------------------
# fetch-simulation reader identity (regression)
# ---------------------------------------------------------------------------

class _InflatedMetaReader(LakeReader):
    """Same path as the real file but reports 1000x encoded_bytes — stands
    in for a reader whose metadata disagrees with another open handle."""

    FACTOR = 1000

    def row_group_meta(self, rg):
        meta = copy.deepcopy(super().row_group_meta(rg))
        for c in meta["columns"].values():
            c["encoded_bytes"] *= self.FACTOR
        return meta


def test_simulate_fetch_uses_contributing_readers_metadata(lineitem):
    """Two reader OBJECTS for one path in one coalesced tick group: the
    fetch simulation must price each row group with the reader that scanned
    it, not whichever request was first in the group (the old code read
    reqs[0].reader for every group member)."""
    low, high = _mouse(200), _mouse(2200)  # disjoint row groups

    def run(second_reader):
        svc = _service(batch_per_tick=2)
        svc.submit("a", lineitem, low)
        svc.submit("b", second_reader, high)
        svc.drain()
        return svc.telemetry.counters["sim_fetch_serial_s"]

    honest = run(LakeReader(lineitem.path))
    inflated = run(_InflatedMetaReader(lineitem.path))
    # the doctored reader's groups must be priced with ITS metadata: the
    # simulated serial fetch grows by orders of magnitude, not noise
    assert inflated > honest * 10, (honest, inflated)


def test_simulate_fetch_sizes_decode_like_the_engine(lineitem):
    """Regression (honest cost model): the fetch simulation used to model
    `n * 4 * len(all_columns)` decoded bytes, but the engine materializes
    PACK_BLOCK-padded rows and never decodes the fused predicate column.
    For a fused plan over a NON-block-aligned row group the simulated
    decoded bytes must equal the engine's actual materialized bytes."""
    last = lineitem.n_row_groups - 1
    n_last = lineitem.row_group_meta(last)["n"]
    assert n_last % 8192 != 0  # precondition: short, non-aligned final group
    # fused: integer Cmp on a BITPACK column outside the projection
    plan = ScanPlan("lineitem", ["l_extendedprice"], Cmp("l_quantity", "le", 10))
    svc = _service()
    t = svc.submit("t", lineitem, plan)
    svc.drain()
    assert t.result.stats.fused  # precondition: the fast path really fused
    sim_dec = svc.telemetry.counters["sim_fetch_decoded_bytes"]
    assert sim_dec == t.result.stats.decoded_bytes, (
        sim_dec, t.result.stats.decoded_bytes)
    # sanity of the old bug's magnitude: the nominal model would have priced
    # rows*4*2 (pred column included, no padding) — a different number
    rows = sum(lineitem.row_group_meta(rg)["n"] for rg in range(lineitem.n_row_groups))
    assert sim_dec != rows * 4 * 2


def test_honest_estimates_reconcile_to_zero(lineitem):
    """For honest metadata the decode-seconds estimate equals the actual
    cost exactly, so reconciliation is a no-op — charges are never churned
    for well-behaved tenants."""
    svc = _service()
    svc.submit("t", lineitem, _elephant())
    svc.drain()
    cost = svc.telemetry.cost_report()["t"]
    assert cost["actual_s"] == pytest.approx(cost["est_s"])
    assert abs(cost["recon_s"]) < 1e-9
    assert abs(cost["rel_err"]) < 1e-9


def test_under_estimating_tenant_is_rebilled(lineitem):
    """Adversarial: a tenant whose request under-prices its decode 4x is
    re-billed to its true cost at slice completion, so its decoded-byte
    share while competing stays at the honest level; with reconciliation
    off the same cheat buys extra share.  Drives the SAME harness the
    `service.costmodel.adversarial` bench reports, so the bench number and
    this bound cannot drift apart."""
    from benchmarks.service_bench import _run_adversarial

    from repro.datapath import CostModel

    cm = CostModel()
    base = _run_adversarial(lineitem, cm, cheat=False, reconcile=True)
    on = _run_adversarial(lineitem, cm, cheat=True, reconcile=True)
    off = _run_adversarial(lineitem, cm, cheat=True, reconcile=False)
    assert on["cheat_share"] <= base["cheat_share"] * 1.10  # < 10% extra share
    assert off["cheat_share"] > on["cheat_share"]  # the cheat did pay off
    # the ledger shows the under-estimate and the correction closing it
    # exactly (rel_err is milder than -0.75 because the adaptive dispatch
    # scale re-prices later slices toward their true cost)
    cost = on["cost"]["cheat"]
    assert cost["rel_err"] < -0.1
    assert cost["recon_s"] == pytest.approx(
        cost["actual_s"] - cost["est_s"], rel=1e-6)


def test_prefiltered_cache_hit_slice_is_refunded(lineitem):
    """A request answered from the prefiltered cache decodes nothing: the
    decode-seconds charged at dispatch must be refunded, not kept as a
    phantom charge against the tenant's share."""
    from repro.datapath import AdaptiveOffloadPolicy

    svc = _service(policy=AdaptiveOffloadPolicy(repeat_k=2))
    plan = PLAN_A
    svc.result(svc.submit("t", lineitem, plan))
    svc.result(svc.submit("t", lineitem, plan))  # promoted + cached
    before = svc.telemetry.tenant_recon_seconds.get("t", 0.0)
    t3 = svc.submit("t", lineitem, plan)
    svc.result(t3)
    assert t3.result.stats.cache_hit
    # the cache-hit slice's whole estimate came back as a refund
    assert svc.telemetry.tenant_recon_seconds["t"] < before - 1e-12
    # ...but a zero-work slice must NOT train the dispatch-price EWMA: it
    # is a scheduling outcome, not an estimate error, and folding it in
    # would let this tenant's next fresh scan dispatch at a floor price
    assert svc._est_scale.get("t", 1.0) == pytest.approx(1.0)


def test_disjoint_footprints_precondition(lineitem):
    """The regression test above needs the two mice to touch different row
    groups; pin that property of the dataset."""
    from repro.core.plan import bind_expr
    from repro.core.zonemap import prune_row_groups
    lo = prune_row_groups(lineitem, bind_expr(_mouse(200).predicate, lineitem))
    hi = prune_row_groups(lineitem, bind_expr(_mouse(2200).predicate, lineitem))
    assert lo and hi and not (set(lo) & set(hi))
