"""ServeEngine: drain semantics, continuous batching, greedy determinism."""

import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config("granite-3-8b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def test_drains_all_requests(served):
    cfg, params = served
    rng = np.random.default_rng(3)
    eng = ServeEngine(params, cfg, n_slots=3, max_len=96)
    for i in range(5):
        eng.submit(Request(rid=i, tokens=rng.integers(0, cfg.vocab, (8 + i,)),
                           max_new_tokens=6))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)
    # continuous batching: 5 requests x 6 tokens on 3 slots must take fewer
    # ticks than serial (30) — slots overlap
    assert eng.steps <= 12


def test_greedy_is_deterministic(served):
    cfg, params = served
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, (12,))
    outs = []
    for _ in range(2):
        eng = ServeEngine(params, cfg, n_slots=2, max_len=64)
        eng.submit(Request(rid=0, tokens=prompt, max_new_tokens=5))
        done = eng.run_until_drained()
        outs.append(done[0].out)
    assert outs[0] == outs[1]
