"""HashRing stability: the three properties the fabric's drain/replay
path leans on (sharding.py docstring) — determinism across processes,
rough balance at 64 virtual replicas, and minimal moved arc under node
add/remove (survivor-owned keys NEVER change owner)."""

from repro.distributed.sharding import HashRing, rg_key

KEYS = [rg_key(f"/lake/t{t}.lake", rg) for t in range(4) for rg in range(128)]


def test_ring_deterministic_across_instances():
    a = HashRing(["pod0", "pod1", "pod2"])
    b = HashRing(["pod0", "pod1", "pod2"])
    assert a.owners(KEYS) == b.owners(KEYS)
    # insertion order of nodes must not matter either
    c = HashRing(["pod2", "pod0", "pod1"])
    assert a.owners(KEYS) == c.owners(KEYS)


def test_ring_balance():
    ring = HashRing([f"pod{i}" for i in range(4)])
    owners = ring.owners(KEYS)
    counts = {n: 0 for n in ring.nodes}
    for o in owners.values():
        counts[o] += 1
    # 512 keys over 4 nodes -> expect ~128 each; virtual points keep the
    # worst node within a loose 3x band of fair share and none starved
    for n, c in counts.items():
        assert 0 < c < 3 * len(KEYS) // 4, (n, c, counts)


def test_ring_minimal_movement_on_remove():
    ring = HashRing(["pod0", "pod1", "pod2"])
    before = ring.owners(KEYS)
    ring.remove_node("pod1")
    after = ring.owners(KEYS)
    for k in KEYS:
        if before[k] != "pod1":
            assert after[k] == before[k], k  # survivors keep their arcs
        else:
            assert after[k] != "pod1"  # dead arcs re-home to survivors


def test_ring_minimal_movement_on_add():
    ring = HashRing(["pod0", "pod1"])
    before = ring.owners(KEYS)
    ring.add_node("pod2")
    after = ring.owners(KEYS)
    moved = [k for k in KEYS if after[k] != before[k]]
    # every moved key moved TO the new node, and it stole a real arc
    assert moved and all(after[k] == "pod2" for k in moved)
    # add + remove round-trips to the original ownership
    ring.remove_node("pod2")
    assert ring.owners(KEYS) == before


def test_ring_add_is_idempotent_and_remove_unknown_is_noop():
    ring = HashRing(["pod0", "pod1"])
    before = ring.owners(KEYS)
    ring.add_node("pod0")
    ring.remove_node("nope")
    assert ring.owners(KEYS) == before and ring.nodes == ["pod0", "pod1"]


def test_ring_empty_raises():
    ring = HashRing()
    try:
        ring.owner("k")
    except ValueError:
        pass
    else:
        raise AssertionError("expected ValueError on empty ring")
