"""End-to-end behaviour tests for the paper's system.

The paper's claim chain, on this framework:
  1. querying raw encoded files spends most time in decode+filter,
  2. a datapath engine that decodes + filters before the consumer removes
     that cost from the consumer's critical path,
  3. pre-filtered consumers match raw-file answer EXACTLY,
  4. the same datapath feeds LM training (bit-packed ingestion) end-to-end.
"""

import os

import numpy as np
import pytest

from repro.core import BlockCache, DatapathEngine, tpch
from repro.core.queries import QUERIES
from repro.data.corpus import write_corpus
from repro.data.pipeline import TokenPipeline
from repro.lakeformat.reader import LakeReader
from repro.train.loop import train
from repro.train.optimizer import OptConfig
from repro.configs import get_smoke_config


def test_offload_configs_same_answers(tmp_path):
    """Fig. 1 invariant: raw / pre-loaded / pre-filtered give identical
    query results — only the work distribution changes."""
    paths = tpch.write_tables(str(tmp_path), sf=0.03, seed=0)
    readers = {k: LakeReader(p) for k, p in paths.items()}
    answers = {}
    for offload in ("raw", "preloaded", "prefiltered"):
        eng = DatapathEngine(backend="ref", offload=offload, cache=BlockCache())
        answers[offload] = {n: q(eng, readers) for n, q in QUERIES.items()}
    assert answers["raw"] == answers["preloaded"] == answers["prefiltered"]


def test_train_e2e_with_datapath(tmp_path):
    """Corpus in the lake -> fused bit-packed batches -> loss goes down ->
    checkpoint -> resume."""
    cfg = get_smoke_config("qwen3-1.7b")
    paths = write_corpus(str(tmp_path / "c"), n_tokens=120_000, vocab=cfg.vocab,
                         n_shards=1, row_group_size=32768)
    pipe = TokenPipeline(paths, batch_size=1, seq_len=4096, mode="fused")
    optcfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10, weight_decay=0.01)
    out = train(cfg, optcfg, pipe, steps=4, ckpt_dir=str(tmp_path / "ck"),
                ckpt_every=2, log_every=10, log_fn=lambda s: None)
    assert out["losses"][-1] < out["losses"][0]
    # resume picks up at step 4
    pipe2 = TokenPipeline(paths, batch_size=1, seq_len=4096, mode="fused")
    out2 = train(cfg, optcfg, pipe2, steps=5, ckpt_dir=str(tmp_path / "ck"),
                 ckpt_every=2, log_every=10, log_fn=lambda s: None)
    assert len(out2["losses"]) == 1
