"""Flight-recorder unit tests: the Tracer's sampling/ring/cap mechanics
on a deterministic counter clock, stage attribution against hand-built
trees, Chrome-trace export shape, and the service-level wiring (every
completed request reconstructable, telemetry `trace` section, p99.9 and
known_tenants satellites).

The hypothesis sweep over scheduler/batch/hold/store configurations —
including the traced-vs-untraced bit-identity property — lives in
tests/test_trace_props.py.
"""

import json

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan
from repro.datapath import (
    PAPER_FIG2_PCT,
    STAGES,
    DatapathService,
    StaticPolicy,
    Telemetry,
    Tracer,
)
from repro.datapath import trace as trace_mod
from repro.lakeformat.reader import LakeReader
from repro.lakeformat.schema import ColumnSchema, TableSchema
from repro.lakeformat.writer import write_table


class FakeClock:
    """Monotonic counter clock: every read advances by `step`."""

    def __init__(self, step: float = 1.0):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


def make_tracer(**kw) -> Tracer:
    kw.setdefault("clock", FakeClock())
    return Tracer(**kw)


@pytest.fixture(scope="module")
def table(tmp_path_factory):
    rng = np.random.default_rng(3)
    n = 4096
    cols = {
        "a": np.arange(n, dtype=np.int32),
        "b": rng.standard_normal(n).astype(np.float32),
    }
    schema = TableSchema("smoke", [
        ColumnSchema("a", "int32", "bitpack"),
        ColumnSchema("b", "float32", "plain"),
    ])
    path = str(tmp_path_factory.mktemp("trace") / "smoke.lake")
    write_table(path, schema, cols, row_group_size=1024)
    return LakeReader(path)


def service(**kw):
    kw.setdefault("engine", DatapathEngine(backend="ref", cache=BlockCache(1 << 30)))
    kw.setdefault("policy", StaticPolicy("raw"))
    return DatapathService(**kw)


# ---------------------------------------------------------------------------
# sampling: deterministic fractional accumulator, no RNG
# ---------------------------------------------------------------------------

def test_sampling_is_deterministic_and_exact():
    tr = make_tracer(sample_rate=0.5)
    picks = [tr.start(i, "t", "tbl") is not None for i in range(8)]
    # accumulator: 0.5 (skip), 1.0 (sample), ... — every second request
    assert picks == [False, True] * 4
    assert tr.sampled == 4 and tr.skipped == 4
    # an identical tracer makes identical picks (no hidden RNG state)
    tr2 = make_tracer(sample_rate=0.5)
    assert [tr2.start(i, "t", "tbl") is not None for i in range(8)] == picks


def test_sampling_rate_one_traces_everything():
    tr = make_tracer(sample_rate=1.0)
    assert all(tr.start(i, "t", "tbl") is not None for i in range(5))
    assert tr.skipped == 0


def test_sampling_fractional_rate_hits_expected_count():
    tr = make_tracer(sample_rate=0.25)
    n = sum(tr.start(i, "t", "tbl") is not None for i in range(100))
    assert n == 25  # exact, not approximate: the accumulator never drifts


def test_rate_zero_disables_the_tracer_entirely(table):
    svc = service(trace_sample_rate=0.0)
    assert svc.tracer is None
    svc.submit("t", table, ScanPlan("smoke", ["b"]))
    svc.drain()
    rep = svc.telemetry.trace_report()
    assert rep == {"enabled": False, "completed": 0, "recorded": 0,
                   "requests": []}


# ---------------------------------------------------------------------------
# ring: bounded memory, completed counts keep running
# ---------------------------------------------------------------------------

def test_ring_keeps_last_capacity_traces():
    tr = make_tracer(capacity=3)
    for i in range(7):
        tr.start(i, f"tenant{i % 2}", "tbl")
        tr.finish(i, "done")
    rec = tr.recorder
    assert rec.completed == 7
    assert [rt.req_id for rt in rec.traces()] == [4, 5, 6]
    rep = tr.report()
    assert rep["completed"] == 7 and rep["recorded"] == 3
    assert [r["req_id"] for r in rep["requests"]] == [4, 5, 6]


# ---------------------------------------------------------------------------
# span cap: overflow drops spans but never desyncs the stack
# ---------------------------------------------------------------------------

def test_max_spans_drop_keeps_stack_discipline():
    tr = make_tracer(max_spans=3)  # root + 2 children
    rt = tr.start(1, "t", "tbl")
    tr.begin(rt, "slice_dispatch")
    tr.begin(rt, "fetch")          # 3rd span: at cap from here on
    tr.begin(rt, "decode_launch")  # dropped
    tr.begin(rt, "inner")          # dropped
    tr.end(rt)                     # matches dropped "inner"
    tr.end(rt)                     # matches dropped "decode_launch"
    tr.end(rt, name="fetch")       # closes the REAL fetch span
    tr.end(rt, name="slice_dispatch")
    tr.finish(1, "done")
    sm = rt.summary
    assert rt.dropped_spans == 2 and rt.drop_depth == 0
    assert sm["spans"] == 3 and sm["dropped_spans"] == 2
    (sd,) = rt.root["children"]
    assert sd["name"] == "slice_dispatch" and sd["t1"] is not None
    (fe,) = sd["children"]
    assert fe["name"] == "fetch" and fe["children"] == []


def test_named_end_closes_dangling_children():
    """An exception between begin(fetch) and its end leaves fetch open;
    the slice's named end must close it (at the same instant) instead of
    mis-attributing the rest of the run to fetch."""
    tr = make_tracer()
    rt = tr.start(1, "t", "tbl")
    tr.begin(rt, "slice_dispatch")
    tr.begin(rt, "fetch")
    # error path: no end for fetch
    tr.end(rt, name="slice_dispatch")
    assert len(rt.stack) == 1  # back at the root
    (sd,) = rt.root["children"]
    (fe,) = sd["children"]
    assert fe["t1"] == sd["t1"]  # closed together, zero residual width
    tr.finish(1, "error")
    assert rt.summary["status"] == "error"


def test_unmatched_end_never_pops_the_root():
    tr = make_tracer()
    rt = tr.start(1, "t", "tbl")
    tr.end(rt)  # nothing open: must be a no-op
    assert rt.stack == [rt.root]
    tr.finish(1, "done")
    assert rt.root["t1"] >= rt.root["t0"]


# ---------------------------------------------------------------------------
# wait-state machine
# ---------------------------------------------------------------------------

def test_wait_extends_same_kind_and_switches_kinds():
    tr = make_tracer()
    rt = tr.start(1, "t", "tbl")
    tr.wait(rt, "hold_window")
    tr.wait(rt, "hold_window")
    tr.wait(rt, "hold_window")
    tr.wait(rt, "wfq_wait")  # kind switch closes the hold span
    tr.wait(rt, "wfq_wait")
    tr.end_wait(rt)
    hold, wfq = rt.root["children"]
    assert hold["name"] == "hold_window" and hold["args"]["ticks"] == 3
    assert wfq["name"] == "wfq_wait" and wfq["args"]["ticks"] == 2
    assert hold["t1"] <= wfq["t0"]  # waits never overlap
    assert rt.wait_kind is None
    tr.finish(1, "done")


def test_finish_closes_an_open_wait():
    tr = make_tracer()
    rt = tr.start(1, "t", "tbl")
    tr.wait(rt, "wfq_wait")
    tr.finish(1, "cancelled")
    (w,) = rt.root["children"]
    assert w["t1"] is not None and rt.summary["status"] == "cancelled"


# ---------------------------------------------------------------------------
# stage attribution
# ---------------------------------------------------------------------------

def test_attribution_maps_spans_and_never_double_bills():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    rt = tr.start(1, "t", "tbl")
    tr.begin(rt, "slice_dispatch")      # unmapped: recursed, not billed
    tr.begin(rt, "fetch")
    tr.event(rt, "store_hit")           # child of a mapped span: ignored
    tr.end(rt, name="fetch")
    tr.begin(rt, "decode_launch")
    tr.end(rt, name="decode_launch")
    tr.begin(rt, "filter")
    tr.end(rt, name="filter")
    tr.end(rt, name="slice_dispatch")
    tr.finish(1, "done")
    sm = rt.summary
    assert set(sm["stages_s"]) == set(STAGES)
    assert sm["stages_s"]["fetch"] > 0
    assert sm["stages_s"]["decode"] > 0  # decode_launch -> decode
    assert sm["stages_s"]["filter"] > 0
    assert sm["stages_s"]["admission"] == 0.0
    assert sm["attributed_s"] <= sm["wall_s"] + 1e-12
    assert 0.0 <= sm["decode_pct"] <= 100.0
    assert abs(sm["decode_pct"] + sm["filter_pct"] + sm["rest_pct"] - 100.0) < 1e-9


def test_report_rolls_up_by_tenant_with_paper_anchor():
    tr = make_tracer()
    for i, tenant in enumerate(("alice", "alice", "bob")):
        rt = tr.start(i, tenant, "tbl")
        tr.begin(rt, "decode_launch")
        tr.end(rt, name="decode_launch")
        tr.finish(i, "done")
    rep = tr.report()
    assert rep["paper_fig2_pct"] == dict(sorted(PAPER_FIG2_PCT.items()))
    assert set(rep["by_tenant"]) == {"alice", "bob"}
    assert rep["by_tenant"]["alice"]["n"] == 2
    for bt in rep["by_tenant"].values():
        assert abs(bt["decode_pct"] + bt["filter_pct"] + bt["rest_pct"]
                   - 100.0) < 1e-9
    # fleet wall is the sum of per-tenant walls
    assert abs(rep["wall_s"]
               - sum(bt["wall_s"] for bt in rep["by_tenant"].values())) < 1e-9


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_shape_and_determinism(tmp_path):
    tr = make_tracer()
    for i, tenant in enumerate(("alice", "bob")):
        rt = tr.start(i, tenant, "tbl")
        tr.begin(rt, "slice_dispatch")
        tr.event(rt, "store_hit", tier="decoded")
        tr.end(rt, name="slice_dispatch")
        tr.finish(i, "done")
    doc = tr.recorder.to_chrome_trace()
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    spans = [e for e in events if e["ph"] == "X"]
    instants = [e for e in events if e["ph"] == "i"]
    assert {e["args"]["name"] for e in meta if e["name"] == "process_name"} \
        == {"alice", "bob"}
    assert all(e["dur"] > 0 and e["ts"] >= 0 for e in spans)
    assert all(e["s"] == "t" for e in instants)
    assert any(e["name"] == "store_hit" for e in instants)
    # export is deterministic and valid JSON
    assert json.dumps(doc, sort_keys=True) == json.dumps(
        tr.recorder.to_chrome_trace(), sort_keys=True)
    path = tmp_path / "trace.json"
    n = tr.recorder.save_chrome_trace(str(path))
    assert n == len(events)
    assert json.loads(path.read_text())["traceEvents"] == json.loads(
        json.dumps(events))


def test_chrome_trace_empty_ring():
    tr = make_tracer()
    assert tr.recorder.to_chrome_trace() == {"displayTimeUnit": "ms",
                                             "traceEvents": []}


# ---------------------------------------------------------------------------
# module-level slice context
# ---------------------------------------------------------------------------

def test_module_hooks_noop_without_slice_context():
    assert trace_mod._CUR is None
    # must not raise, must not allocate a trace anywhere
    trace_mod.begin("fetch")
    trace_mod.event("store_hit")
    trace_mod.end(name="fetch")


def test_module_hooks_record_into_published_slice():
    tr = make_tracer()
    rt = tr.start(1, "t", "tbl")
    trace_mod.set_slice(tr, rt)
    try:
        trace_mod.begin("fetch", rg=0)
        trace_mod.event("store_hit", tier="encoded")
        trace_mod.end(name="fetch", nbytes=10)
    finally:
        trace_mod.set_slice(None, None)
    (fe,) = rt.root["children"]
    assert fe["name"] == "fetch" and fe["args"]["nbytes"] == 10
    assert fe["children"][0]["name"] == "store_hit"
    tr.finish(1, "done")


# ---------------------------------------------------------------------------
# service integration: the full lifecycle is reconstructable
# ---------------------------------------------------------------------------

def test_service_traces_full_lifecycle(table):
    svc = service(hold_ticks=2, tick_bytes=1024 * 8, trace_capacity=8)
    svc.submit("alice", table, ScanPlan("smoke", ["b"],
                                        Cmp("a", "lt", 3000)))
    svc.submit("bob", table, ScanPlan("smoke", ["a", "b"]))
    svc.drain()
    rep = svc.telemetry.trace_report()
    assert rep["enabled"] and rep["completed"] == 2 == rep["recorded"]
    names_by_req = {}
    for rt in svc.tracer.recorder.traces():
        seen = set()
        stack = [rt.root]
        while stack:
            sp = stack.pop()
            seen.add(sp["name"])
            assert sp["t1"] is not None
            stack.extend(sp["children"])
        names_by_req[rt.req_id] = seen
        sm = rt.summary
        assert sm["status"] == "done"
        assert sm["attributed_s"] <= sm["wall_s"] + 1e-9
        assert sm["done_tick"] >= sm["submitted_tick"]
    for names in names_by_req.values():
        assert {"request", "admission", "slice_dispatch",
                "decode_launch"} <= names
    # the sliced multi-tick request waited in the WFQ queue at least once
    assert any("wfq_wait" in names for names in names_by_req.values())


def test_service_trace_survives_snapshot(table):
    svc = service(trace_capacity=4)
    svc.submit("t", table, ScanPlan("smoke", ["b"]))
    svc.drain()
    snap = svc.telemetry.snapshot()
    assert snap["trace"]["recorded"] == 1
    assert "tick_p999_s" in snap
    assert json.dumps(snap["trace"], sort_keys=True)  # JSON-serializable


# ---------------------------------------------------------------------------
# telemetry satellites: known_tenants union, p99.9 keys
# ---------------------------------------------------------------------------

def test_known_tenants_unions_actual_and_recon_seconds():
    tm = Telemetry()
    tm.observe_actual_cost("only-actual", 0.5)
    tm.observe_recon("only-recon", -0.1)
    assert "only-actual" in tm.known_tenants()
    assert "only-recon" in tm.known_tenants()
    cost = tm.cost_report()
    assert cost["only-actual"]["actual_s"] == 0.5
    assert cost["only-recon"]["recon_s"] == -0.1


def test_p999_in_latency_fairness_and_snapshot():
    tm = Telemetry()
    for i in range(1000):
        tm.observe_latency("t", float(i))
        tm.observe_tick(float(i) / 10.0)
    lat = tm.tenant_latency("t")
    assert lat["p999_s"] >= lat["p99_s"] >= lat["p50_s"]
    # nearest-rank half-up over 1000 samples: rank floor(0.999*999+0.5)=998
    assert lat["p999_s"] == 998.0
    fair = tm.fairness()
    assert fair["tenant_latency_p999_s"]["t"] == 998.0
    snap = tm.snapshot()
    assert snap["tick_p999_s"] >= snap["tick_p99_s"]
