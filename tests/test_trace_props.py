"""Property suite for the flight recorder's span invariants (DESIGN.md
§13), swept across the service's configuration space: sequential vs
batched dispatch, fifo vs wfq, hold windows, slice splits (tick_bytes)
and store-hit paths (preloaded / prefiltered repeats).

Invariants, per completed request:
  1. the span tree is WELL-FORMED — every span has t0 <= t1 and every
     child's interval nests inside its parent's (within eps);
  2. stage attribution never over-bills — attributed_s <= wall_s + eps,
     because mapped spans' children are not recursed and wait spans are
     closed before slice dispatch;
  3. every admitted request is reconstructable — root + admission spans,
     terminal status, done_tick >= submitted_tick;
  4. the Chrome-trace export is deterministic — two exports of the same
     ring serialize to byte-identical JSON, and every event carries
     JSON-safe key-sorted args;
  5. tracing never perturbs results — scan output (count, columns, mask)
     is bit-identical between a traced service and trace_sample_rate=0.

Fixed cases always run; the hypothesis sweep (skipped without
`hypothesis`, same policy as tests/test_batch_decode.py) drives random
configuration mixes over the same invariants.
"""

import json

import numpy as np
import pytest

from repro.core import BlockCache, Cmp, DatapathEngine, ScanPlan
from repro.datapath import DatapathService, StaticPolicy
from repro.lakeformat.reader import LakeReader
from repro.lakeformat.schema import ColumnSchema, TableSchema
from repro.lakeformat.writer import write_table

EPS = 1e-9
RG_ROWS = 900  # ragged: not a PACK_BLOCK multiple


@pytest.fixture(scope="module")
def mixed(tmp_path_factory):
    """Small mixed-encoding table, 5 ragged row groups — enough for
    multi-slice dispatch under a tight tick_bytes."""
    rng = np.random.default_rng(11)
    n = 4 * RG_ROWS + 420
    cols = {
        "ts": np.arange(n, dtype=np.int32),                       # delta
        "flag": np.repeat(rng.integers(0, 4, n // 60 + 1),
                          60)[:n].astype(np.int32),               # rle
        "price": rng.standard_normal(n).astype(np.float32),       # plain
        "key": rng.integers(0, 1 << 11, n).astype(np.int32),      # bitpack
    }
    schema = TableSchema("mixed", [
        ColumnSchema("ts", "int32", "delta"),
        ColumnSchema("flag", "int32", "rle"),
        ColumnSchema("price", "float32", "plain"),
        ColumnSchema("key", "int32", "bitpack"),
    ])
    path = str(tmp_path_factory.mktemp("traceprops") / "mixed.lake")
    write_table(path, schema, cols, row_group_size=RG_ROWS)
    return LakeReader(path)


PLANS = [
    ScanPlan("mixed", ["price"], Cmp("ts", "lt", 2 * RG_ROWS)),
    ScanPlan("mixed", ["price", "flag"], Cmp("key", "lt", 700)),
    ScanPlan("mixed", ["ts", "price"]),
    ScanPlan("mixed", ["flag"], Cmp("flag", "between", (1, 2))),
]


def build(c, tracing: bool) -> DatapathService:
    return DatapathService(
        engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
        policy=StaticPolicy(c["offload"]),
        scheduler=c["scheduler"],
        batch_decode=c["batch_decode"],
        hold_ticks=c["hold_ticks"],
        tick_bytes=c["tick_bytes"],
        trace_sample_rate=1.0 if tracing else 0.0,
        trace_capacity=16,
    )


def run_workload(svc, c, reader):
    tickets = []
    for i in range(c["n_reqs"]):
        tickets.append(svc.submit(f"tenant{i % 2}", reader,
                                  PLANS[i % len(PLANS)]))
        if c["hold_ticks"] and i == 0:
            svc.tick()  # let the first request enter its hold window
    svc.drain()
    if c["repeat"]:  # second pass hits the store (preloaded/prefiltered)
        tickets.append(svc.submit("tenant0", reader, PLANS[0]))
        svc.drain()
    return tickets


def check_tree(sp, lo, hi):
    """Recursive well-formedness: t0 <= t1, interval within [lo, hi]."""
    assert sp["t1"] is not None, sp["name"]
    assert sp["t0"] <= sp["t1"] + EPS, sp["name"]
    assert sp["t0"] >= lo - EPS and sp["t1"] <= hi + EPS, sp["name"]
    for c in sp["children"]:
        check_tree(c, sp["t0"], sp["t1"])


def check_span_invariants(svc, tickets):
    """Invariants 1-4 over a drained traced service's flight recorder."""
    traces = svc.tracer.recorder.traces()
    # (3) every admitted request is reconstructable
    assert len(traces) == len(tickets)
    assert {rt.req_id for rt in traces} == {t.req_id for t in tickets}
    for rt in traces:
        root = rt.root
        # (1) well-formed tree
        check_tree(root, root["t0"], root["t1"])
        assert root["name"] == "request"
        assert root["children"][0]["name"] == "admission"
        sm = rt.summary
        assert sm["status"] == "done"
        assert sm["done_tick"] >= sm["submitted_tick"]
        # (2) attribution never over-bills the wall
        assert sm["attributed_s"] <= sm["wall_s"] + EPS
        assert sum(sm["stages_s"].values()) == pytest.approx(sm["attributed_s"])
        assert sm["rest_pct"] >= -EPS
    # (4) deterministic export, JSON-safe key-sorted args
    doc = svc.tracer.recorder.to_chrome_trace()
    blob = json.dumps(doc, sort_keys=True)
    assert blob == json.dumps(svc.tracer.recorder.to_chrome_trace(),
                              sort_keys=True)
    for e in json.loads(blob)["traceEvents"]:
        assert list(e["args"]) == sorted(e["args"])


def check_bit_identity(traced, plain):
    """Invariant 5: identical tickets from traced and untraced runs."""
    assert len(traced) == len(plain)
    for a, b in zip(traced, plain):
        ra, rb = a.result, b.result
        assert a.status == b.status == "done"
        assert int(ra.count) == int(rb.count)
        assert set(ra.columns) == set(rb.columns)
        for name in ra.columns:
            np.testing.assert_array_equal(
                np.asarray(ra.columns[name]), np.asarray(rb.columns[name]))
        if ra.mask is not None or rb.mask is not None:
            np.testing.assert_array_equal(
                np.asarray(ra.mask), np.asarray(rb.mask))


# ---------------------------------------------------------------------------
# fixed sweep — always runs; one case per scheduler/dispatch/hold/store axis
# ---------------------------------------------------------------------------

FIXED_CASES = [
    dict(scheduler="fifo", batch_decode=False, hold_ticks=0, tick_bytes=None,
         offload="raw", n_reqs=2, repeat=False),
    dict(scheduler="wfq", batch_decode=True, hold_ticks=0, tick_bytes=None,
         offload="raw", n_reqs=3, repeat=False),
    dict(scheduler="wfq", batch_decode=True, hold_ticks=2,
         tick_bytes=RG_ROWS * 4 * 2, offload="raw", n_reqs=4, repeat=False),
    dict(scheduler="wfq", batch_decode=False, hold_ticks=2,
         tick_bytes=RG_ROWS * 4 * 2, offload="preloaded", n_reqs=2,
         repeat=True),
    dict(scheduler="fifo", batch_decode=True, hold_ticks=0, tick_bytes=None,
         offload="prefiltered", n_reqs=2, repeat=True),
]

IDS = ["seq-fifo", "batch-wfq", "sliced-hold", "preloaded-repeat",
       "prefiltered-repeat"]


@pytest.mark.parametrize("c", FIXED_CASES, ids=IDS)
def test_span_invariants_fixed(mixed, c):
    svc = build(c, tracing=True)
    tickets = run_workload(svc, c, mixed)
    check_span_invariants(svc, tickets)


@pytest.mark.parametrize("c", FIXED_CASES, ids=IDS)
def test_bit_identity_fixed(mixed, c):
    check_bit_identity(run_workload(build(c, tracing=True), c, mixed),
                       run_workload(build(c, tracing=False), c, mixed))


def test_ring_and_sampler_accounting(mixed):
    for n_reqs, rate in [(1, 1.0), (4, 0.5), (5, 0.5), (5, 1.0)]:
        svc = DatapathService(
            engine=DatapathEngine(backend="ref", cache=BlockCache(1 << 30)),
            policy=StaticPolicy("raw"),
            trace_sample_rate=rate, trace_capacity=2,
        )
        for i in range(n_reqs):
            svc.submit("t", mixed, PLANS[i % len(PLANS)])
        svc.drain()
        tr = svc.tracer
        expect_sampled = int(n_reqs * rate)  # exact: fractional accumulator
        assert tr.sampled == expect_sampled
        assert tr.sampled + tr.skipped == n_reqs
        rep = tr.report()
        assert rep["completed"] == expect_sampled
        assert rep["recorded"] == min(2, expect_sampled)  # ring capacity
        assert rep["live"] == 0


# ---------------------------------------------------------------------------
# hypothesis sweep
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    cfg = st.fixed_dictionaries({
        "scheduler": st.sampled_from(["fifo", "wfq"]),
        "batch_decode": st.booleans(),
        "hold_ticks": st.sampled_from([0, 2]),
        "tick_bytes": st.sampled_from([None, RG_ROWS * 4 * 2]),
        "offload": st.sampled_from(["raw", "preloaded", "prefiltered"]),
        "n_reqs": st.integers(1, 4),
        "repeat": st.booleans(),  # re-run plan 0 => store-hit path
    })

    class TestTraceSweep:
        @given(cfg)
        @settings(deadline=None, max_examples=15)
        def test_span_invariants(self, mixed, c):
            svc = build(c, tracing=True)
            tickets = run_workload(svc, c, mixed)
            check_span_invariants(svc, tickets)

        @given(cfg)
        @settings(deadline=None, max_examples=15)
        def test_bit_identity(self, mixed, c):
            check_bit_identity(
                run_workload(build(c, tracing=True), c, mixed),
                run_workload(build(c, tracing=False), c, mixed))
