"""Test helpers: subprocess runner for multi-device (fake-device) tests."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 480) -> str:
    """Run `code` in a fresh python with N fake host devices.

    Multi-device tests must not pollute the main pytest process (jax locks
    the device count at first init), hence subprocesses."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + os.path.join(REPO, "benchmarks")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
